"""Attention + MLP layers (GQA, qk-norm, softcap, sliding window, biases).

Attention supports three entry modes with one code path:
  * train / prefill: full-sequence queries, causal (or bidirectional for
    encoders), optionally writing a KV cache;
  * decode: single-token queries against a cache, with position masking;
  * cross-attention: ``kv_x`` from the encoder, bidirectional mask.

Sliding-window (gemma2 local layers) is a mask refinement — the KV ring
buffer is the paper's C3 window pipeline in one dimension and is implemented
in repro.serve.cache as an optimization on top of this layer.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import (ACTIVATIONS, apply_rope, dense_init,
                                 rms_norm, rope_freqs, softcap)
from repro.ops import dense as dense_op
from repro.sharding.logical import A, ShardingCtx, shard

__all__ = ["AttnConfig", "attn_init", "attn_axes", "attention",
           "MLPConfig", "mlp_init", "mlp_axes", "mlp_apply", "make_attn_mask"]

_NEG_INF = -1e30


@dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_softcap: float | None = None
    rope_theta: float = 10000.0
    use_rope: bool = True


def attn_init(key: jax.Array, cfg: AttnConfig, *, cross: bool = False) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), d),
        "wk": dense_init(ks[1], (d, kv, hd), d),
        "wv": dense_init(ks[2], (d, kv, hd), d),
        "wo": dense_init(ks[3], (h, hd, d), h * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd))
        p["bk"] = jnp.zeros((kv, hd))
        p["bv"] = jnp.zeros((kv, hd))
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,))
        p["k_norm"] = jnp.ones((hd,))
    return p


def attn_axes(cfg: AttnConfig) -> dict:
    ax = {
        "wq": A("embed", "heads", "head"),
        "wk": A("embed", "kv_heads", "head"),
        "wv": A("embed", "kv_heads", "head"),
        "wo": A("heads", "head", "embed"),
    }
    if cfg.qkv_bias:
        ax["bq"] = A("heads", "head")
        ax["bk"] = A("kv_heads", "head")
        ax["bv"] = A("kv_heads", "head")
    if cfg.qk_norm:
        ax["q_norm"] = A(None)
        ax["k_norm"] = A(None)
    return ax


def make_attn_mask(q_pos: jax.Array, kv_pos: jax.Array, *,
                   causal: bool, window: int | None,
                   kv_len: jax.Array | None = None) -> jax.Array:
    """Boolean mask (B, Sq, Skv): True = attend.

    q_pos: (B, Sq); kv_pos: (Skv,) or (B, Skv); kv_len: (B,) number of valid
    cache slots (decode) or None (dense).
    """
    if kv_pos.ndim == 1:
        kv_pos = kv_pos[None, :]
    qp = q_pos[:, :, None]                       # (B, Sq, 1)
    kp = kv_pos[:, None, :]                      # (B, 1, Skv)
    mask = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= (qp - kp) < window
    if kv_len is not None:
        mask &= kp < kv_len[:, None, None]
    return mask


def attention(params: dict, x: jax.Array, cfg: AttnConfig,
              ctx: ShardingCtx | None, *,
              q_pos: jax.Array,
              causal: bool = True,
              window: int | None = None,
              window_active: jax.Array | None = None,
              kv_x: jax.Array | None = None,
              kv_pos: jax.Array | None = None,
              cache_kv: tuple[jax.Array, jax.Array] | None = None,
              cache_index: jax.Array | None = None,
              precomputed_kv: tuple[jax.Array, jax.Array] | None = None,
              kv_valid_len: jax.Array | None = None,
              ) -> tuple[jax.Array, tuple[jax.Array, jax.Array] | None]:
    """Returns (out (B,S,D), updated (k_cache, v_cache) or None).

    cache_kv: (B, S_max, KV, hd) ×2. When given with ``cache_index`` — a ()
    scalar (all rows at one offset) or a (B,) vector (per-row offsets: the
    serve engine's continuous-batching slots, DESIGN.md §6) — the new K/V
    are written at that offset and attention runs over the whole cache with
    position masking (decode / chunked prefill).

    ``window``: static sliding-window size; ``window_active``: optional
    traced bool (per-layer flag under scan — gemma2's local/global
    alternation) selecting between windowed and full masks.
    """
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
    if precomputed_kv is not None:
        k, v = precomputed_kv
        k = k.astype(x.dtype)
        v = v.astype(x.dtype)
    else:
        k = jnp.einsum("btd,dhk->bthk", src, params["wk"].astype(x.dtype))
        v = jnp.einsum("btd,dhk->bthk", src, params["wv"].astype(x.dtype))
        if cfg.qkv_bias:
            k = k + params["bk"].astype(x.dtype)
            v = v + params["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        if precomputed_kv is None:
            k = rms_norm(k, params["k_norm"])

    q = shard(q, ctx, "attn_batch", "act_seq", "act_heads", None)
    k = shard(k, ctx, "attn_batch", "act_seq", "act_kv", None)
    v = shard(v, ctx, "attn_batch", "act_seq", "act_kv", None)

    if kv_pos is None:
        kv_pos = (jnp.arange(k.shape[1])[None, :]
                  if (precomputed_kv is not None or kv_x is not None)
                  else q_pos)
    if cfg.use_rope and kv_x is None and precomputed_kv is None:
        qc, qs_ = rope_freqs(q_pos, hd, cfg.rope_theta)
        kc, ks_ = rope_freqs(kv_pos, hd, cfg.rope_theta)
        q = apply_rope(q, qc, qs_)
        k = apply_rope(k, kc, ks_)

    new_cache = None
    kv_len = None
    if cache_kv is not None:
        ck, cv = cache_kv
        if cache_index is not None:
            if getattr(cache_index, "ndim", 0) == 1:
                # per-row write offsets: each slot advances independently
                def _write(c, new, i):
                    return jax.lax.dynamic_update_slice_in_dim(
                        c, new, i, axis=0)
                ck = jax.vmap(_write)(ck, k.astype(ck.dtype), cache_index)
                cv = jax.vmap(_write)(cv, v.astype(cv.dtype), cache_index)
            else:
                ck = jax.lax.dynamic_update_slice_in_dim(
                    ck, k.astype(ck.dtype), cache_index, axis=1)
                cv = jax.lax.dynamic_update_slice_in_dim(
                    cv, v.astype(cv.dtype), cache_index, axis=1)
        k, v = ck.astype(x.dtype), cv.astype(x.dtype)
        k = shard(k, ctx, "batch", "kv_seq", "act_kv", None)
        v = shard(v, ctx, "batch", "kv_seq", "act_kv", None)
        new_cache = (ck, cv)
        t = ck.shape[1]
        kv_pos_full = jnp.arange(t)
        kv_len = jnp.broadcast_to(cache_index + s, (b,)) \
            if cache_index is not None else None
        mask = make_attn_mask(q_pos, kv_pos_full, causal=causal,
                              window=None, kv_len=kv_len)
        if window is not None:
            wmask = make_attn_mask(q_pos, kv_pos_full, causal=causal,
                                   window=window, kv_len=kv_len)
            active = True if window_active is None else window_active
            mask = jnp.where(active, wmask, mask)
    else:
        mask = make_attn_mask(q_pos, kv_pos, causal=causal, window=None,
                              kv_len=kv_valid_len)
        if window is not None:
            wmask = make_attn_mask(q_pos, kv_pos, causal=causal, window=window,
                                   kv_len=kv_valid_len)
            active = True if window_active is None else window_active
            mask = jnp.where(active, wmask, mask)

    # merged-head layout with KV repeated to full heads: a (kv, groups)
    # score factorization cannot shard when kv_heads < model size, which
    # replicates the whole attention per model rank; repeating KV keeps the
    # head dim shardable (each TP rank holds the duplicate kv head it
    # needs — the standard TP treatment of GQA).
    g = h // kvh
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        seq_name = "kv_seq" if cache_kv is not None else "act_seq"
        k = shard(k, ctx, "attn_batch", seq_name, "act_heads", None)
        v = shard(v, ctx, "attn_batch", seq_name, "act_heads", None)
    if s > _Q_BLOCK:
        out = _blockwise_attn(q, k, v, mask, cfg.attn_softcap)
    else:
        scores = jnp.einsum("bshd,bthd->bhst", q, k) \
            / jnp.sqrt(hd).astype(x.dtype)
        scores = softcap(scores, cfg.attn_softcap)
        scores = jnp.where(mask[:, None, :, :],
                           scores.astype(jnp.float32), _NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhst,bthd->bshd", probs, v)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    out = shard(out, ctx, "batch", "act_seq", "act_embed")
    return out, new_cache


_Q_BLOCK = 512


def _pick_q_block(s: int, cap: int = _Q_BLOCK) -> int:
    qb = min(cap, s)
    while s % qb:
        qb -= 1
    return qb


def _blockwise_attn(q: jax.Array, k: jax.Array, v: jax.Array,
                    mask: jax.Array, attn_softcap: float | None
                    ) -> jax.Array:
    """Query-blockwise attention: never materializes the (S, T) score map.

    A full (B, H, S, T) fp32 score tensor at train shapes is ~40 GB/device
    when the head count does not divide the model axis (llama4: 40 heads vs
    model=16) — measured in the dry-run. Scanning query blocks keeps the
    live set to (B, H, qb, T) per step; the body is remat'd so backward
    recomputes each block's probs instead of saving them (FlashAttention's
    memory behavior, expressed at the XLA level — the Pallas fused kernel
    is the further step for real-TPU wall time).

    q, k, v: (B, S|T, H, hd) — KV already repeated to full heads.
    """
    b, s, h, hd = q.shape
    qb = _pick_q_block(s)
    nb = s // qb
    scale = 1.0 / np.sqrt(hd)
    qs = jnp.moveaxis(q.reshape(b, nb, qb, h, hd), 1, 0)
    ms = jnp.moveaxis(mask.reshape(b, nb, qb, -1), 1, 0)

    def body(_, inp):
        qb_, mb_ = inp
        scores = jnp.einsum("bshd,bthd->bhst", qb_, k) * scale
        scores = softcap(scores, attn_softcap)
        scores = jnp.where(mb_[:, None, :, :],
                           scores.astype(jnp.float32), _NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(qb_.dtype)
        return None, jnp.einsum("bhst,bthd->bshd", probs, v)

    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable,
                          prevent_cse=False)
    _, outs = jax.lax.scan(body, None, (qs, ms))       # (nb,B,qb,H,hd)
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, h, hd)


@dataclass(frozen=True)
class MLPConfig:
    d_model: int
    d_ff: int
    act: str = "silu"
    gated: bool = True
    use_bias: bool = False


def mlp_init(key: jax.Array, cfg: MLPConfig) -> dict:
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], (cfg.d_model, cfg.d_ff), cfg.d_model),
         "wo": dense_init(ks[1], (cfg.d_ff, cfg.d_model), cfg.d_ff)}
    if cfg.gated:
        p["wg"] = dense_init(ks[2], (cfg.d_model, cfg.d_ff), cfg.d_model)
    if cfg.use_bias:
        p["bi"] = jnp.zeros((cfg.d_ff,))
        p["bo"] = jnp.zeros((cfg.d_model,))
    return p


def mlp_axes(cfg: MLPConfig) -> dict:
    ax = {"wi": A("embed", "mlp"), "wo": A("mlp", "embed")}
    if cfg.gated:
        ax["wg"] = A("embed", "mlp")
    if cfg.use_bias:
        ax["bi"] = A("mlp")
        ax["bo"] = A(None)
    return ax


def mlp_apply(params: dict, x: jax.Array, cfg: MLPConfig,
              ctx: ShardingCtx | None) -> jax.Array:
    """Dense matmuls route through the repro.ops ``dense`` entry point, so
    an active ``use_policy(ExecPolicy(quant="int8"))`` moves the MLP onto
    the int8 datapath (kernels/qmatmul) without threading flags here."""
    act = ACTIVATIONS[cfg.act]
    hid = dense_op(x, params["wi"].astype(x.dtype),
                   params["bi"].astype(x.dtype) if cfg.use_bias else None)
    if cfg.gated:
        gate = dense_op(x, params["wg"].astype(x.dtype))
        hid = act(gate) * hid
    else:
        hid = act(hid)
    hid = shard(hid, ctx, "batch", "act_seq", "act_mlp")
    out = dense_op(hid, params["wo"].astype(x.dtype),
                   params["bo"].astype(x.dtype) if cfg.use_bias else None)
    return shard(out, ctx, "batch", "act_seq", "act_embed")
