"""RWKV-6 "Finch" — data-dependent-decay linear attention [arXiv:2404.05892].

Defining feature kept faithfully: the per-channel decay w_t is a function
of the input (via a small LoRA), so the recurrence
  S_t = diag(w_t) · S_{t-1} + k_tᵀ · v_t
  y_t = r_t · (S_{t-1} + diag(u) k_tᵀ v_t)
has token-dependent forgetting. Token shift (x_{t-1} ↔ x_t lerp) is a K=2
causal window — the degenerate form of the paper's line buffer; decode
carries a single-sample shift state (DESIGN.md §5).

Time mixing runs as a chunked scan: within a chunk of length q the
contributions are computed with cumprod-decay contractions (GLA-style),
across chunks a lax.scan carries the (H, dk, dv) state — O(T·q) work with
O(T/q) sequential steps instead of O(T).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, layer_norm
from repro.sharding.logical import A, ShardingCtx, shard

__all__ = ["RWKV6Config", "rwkv6_init", "rwkv6_axes", "rwkv6_apply",
           "rwkv6_decode_step", "rwkv6_state_shape"]


@dataclass(frozen=True)
class RWKV6Config:
    d_model: int
    d_ff: int
    head_dim: int = 64
    lora_rank: int = 64
    chunk: int = 64

    @property
    def n_heads(self) -> int:
        assert self.d_model % self.head_dim == 0
        return self.d_model // self.head_dim


def rwkv6_init(key: jax.Array, cfg: RWKV6Config) -> dict:
    ks = jax.random.split(key, 12)
    d, f, r = cfg.d_model, cfg.d_ff, cfg.lora_rank
    h, hd = cfg.n_heads, cfg.head_dim
    return {
        # pre-mix LayerNorms (official RWKV block layout)
        "ln1": jnp.ones((d,)), "ln1_b": jnp.zeros((d,)),
        "ln2": jnp.ones((d,)), "ln2_b": jnp.zeros((d,)),
        # time mixing
        "mix": 0.5 * jnp.ones((5, d)),            # r,k,v,w,g static lerp
        "w0": jnp.linspace(-6.0, -1.0, d),        # base log-log decay
        "w_lora_a": dense_init(ks[0], (d, r), d),
        "w_lora_b": dense_init(ks[1], (r, d), r) * 0.1,
        "u": jnp.zeros((h, hd)),                  # current-token bonus
        "wr": dense_init(ks[2], (d, d), d),
        "wk": dense_init(ks[3], (d, d), d),
        "wv": dense_init(ks[4], (d, d), d),
        "wg": dense_init(ks[5], (d, d), d),
        "wo": dense_init(ks[6], (d, d), d),
        "ln_x": jnp.ones((d,)),                   # per-head group norm scale
        # channel mixing
        "cmix": 0.5 * jnp.ones((2, d)),           # k,r lerp
        "ck": dense_init(ks[7], (d, f), d),
        "cv": dense_init(ks[8], (f, d), f),
        "cr": dense_init(ks[9], (d, d), d),
    }


def rwkv6_axes(cfg: RWKV6Config) -> dict:
    return {
        "ln1": A(None), "ln1_b": A(None), "ln2": A(None), "ln2_b": A(None),
        "mix": A(None, None), "w0": A(None),
        "w_lora_a": A("embed", None), "w_lora_b": A(None, "embed"),
        "u": A("ssm_heads", None),
        "wr": A("embed", "ssm_inner"), "wk": A("embed", "ssm_inner"),
        "wv": A("embed", "ssm_inner"), "wg": A("embed", "ssm_inner"),
        "wo": A("ssm_inner", "embed"), "ln_x": A(None),
        "cmix": A(None, None),
        "ck": A("embed", "mlp"), "cv": A("mlp", "embed"),
        "cr": A("embed", "ssm_inner"),
    }


def _token_shift(x: jax.Array, prev: jax.Array | None = None) -> jax.Array:
    """x_{t-1} stream: (B,T,D) -> (B,T,D). prev: (B,D) decode shift state."""
    if prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]
    return prev[:, None, :]


def _group_norm(x: jax.Array, scale: jax.Array, n_heads: int,
                eps: float = 1e-5) -> jax.Array:
    """Per-head LayerNorm over head_dim (RWKV's ln_x)."""
    b, t, d = x.shape
    xh = x.reshape(b, t, n_heads, d // n_heads).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = ((xh - mu) ** 2).mean(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(b, t, d) * scale.astype(jnp.float32)).astype(x.dtype)


def _wkv_chunked(r, k, v, logw, u, state, chunk: int):
    """Chunked WKV recurrence.

    r,k,v: (B,T,H,hd); logw: (B,T,H,hd) (log decay, < 0); u: (H,hd);
    state: (B,H,hd,hd) initial. Returns (y (B,T,H,hd), final state).
    """
    b, t, h, n = r.shape
    q = chunk
    assert t % q == 0, (t, q)
    nc = t // q
    rs = r.reshape(b, nc, q, h, n)
    ks = k.reshape(b, nc, q, h, n)
    vs = v.reshape(b, nc, q, h, n)
    lw = logw.reshape(b, nc, q, h, n).astype(jnp.float32)

    # cumulative decay within chunk: W[i] = exp(Σ_{j<=i} logw_j)
    cum = jnp.cumsum(lw, axis=2)                        # (B,nc,q,H,N)
    # decay applied to incoming state at position i: product of w_1..w_i —
    # note RWKV applies decay to S BEFORE adding kᵀv of the current token,
    # and the current token contributes via the u-bonus instead.
    dec_in = jnp.exp(cum - lw)                          # Π_{j<i} w_j  (j<i ⇒ exclusive)
    # key j's contribution surviving to the chunk end: Π_{j<m<=q-1} w_m
    dec_out = jnp.exp(cum[:, :, -1:, :, :] - cum)       # (B,nc,q,H,N)

    # intra-chunk token-to-token: key j visible to query i>j with decay
    # Π_{j<m<i} w_m = exp(cum[i-1] - cum[j]); plus the u-bonus at i=j.
    ci = cum - lw                                       # cum exclusive (Σ_{m<i})
    # pair decay exponent (B,nc,i,j,H,N): clamp masked entries BEFORE exp so
    # neither value nor gradient can overflow (j >= i region is dropped).
    expo = ci[:, :, :, None, :, :] - cum[:, :, None, :, :, :]
    mask = jnp.tril(jnp.ones((q, q), bool), -1)[None, None, :, :, None, None]
    pair = jnp.exp(jnp.where(mask, expo, -1e30)) * mask  # strictly j < i

    att = jnp.einsum("bzihn,bzjhn,bzijhn->bzijh",
                     rs.astype(jnp.float32), ks.astype(jnp.float32), pair)
    y_intra = jnp.einsum("bzijh,bzjhm->bzihm", att, vs.astype(jnp.float32))
    # u-bonus (current token)
    bonus = jnp.einsum("bzihn,hn,bzihn->bzih",
                       rs.astype(jnp.float32), u.astype(jnp.float32),
                       ks.astype(jnp.float32))
    y_intra = y_intra + bonus[..., None] * vs.astype(jnp.float32)

    # per-chunk state update pieces
    chunk_k = jnp.einsum("bzjhn,bzjhn,bzjhm->bzhnm",
                         ks.astype(jnp.float32), dec_out,
                         vs.astype(jnp.float32))        # (B,nc,H,N,M)
    chunk_decay = jnp.exp(cum[:, :, -1])                # (B,nc,H,N)

    def scanf(carry, inp):
        ck_, cd_, = inp
        new = carry * cd_[..., None] + ck_
        return new, carry

    final, prev = jax.lax.scan(
        scanf, state.astype(jnp.float32),
        (jnp.moveaxis(chunk_k, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev = jnp.moveaxis(prev, 0, 1)                     # (B,nc,H,N,M)

    y_state = jnp.einsum("bzihn,bzihn,bzhnm->bzihm",
                         rs.astype(jnp.float32), dec_in, prev)
    y = (y_intra + y_state).reshape(b, t, h, n)
    return y, final


def rwkv6_apply(params: dict, x: jax.Array, cfg: RWKV6Config,
                ctx: ShardingCtx | None,
                state: dict | None = None) -> tuple[jax.Array, dict | None]:
    """One RWKV6 block (time-mix + channel-mix). x: (B,T,D).

    state (decode): {"shift_t","shift_c": (B,D), "wkv": (B,H,hd,hd)}.
    T must be divisible by cfg.chunk in the parallel path (T=1 decode uses
    the recurrent path).
    """
    b, t, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    decode = state is not None and t == 1

    # ---- time mixing (on the LN'd stream, residual to raw x) ----
    xin = layer_norm(x, params["ln1"], params["ln1_b"])
    prev_t = state["shift_t"] if decode else None
    xprev = _token_shift(xin, prev_t)
    mix = params["mix"].astype(x.dtype)
    lerp = lambda i: xin + (xprev - xin) * mix[i]
    xr, xk, xv, xw, xg = (lerp(i) for i in range(5))

    r = jnp.einsum("btd,de->bte", xr, params["wr"].astype(x.dtype))
    k = jnp.einsum("btd,de->bte", xk, params["wk"].astype(x.dtype))
    v = jnp.einsum("btd,de->bte", xv, params["wv"].astype(x.dtype))
    g = jax.nn.silu(jnp.einsum("btd,de->bte", xg,
                               params["wg"].astype(x.dtype)))
    # data-dependent decay (the Finch contribution)
    wlo = jnp.tanh(jnp.einsum("btd,dr->btr", xw.astype(jnp.float32),
                              params["w_lora_a"].astype(jnp.float32)))
    wlo = jnp.einsum("btr,rd->btd", wlo, params["w_lora_b"].astype(jnp.float32))
    logw = -jnp.exp(params["w0"].astype(jnp.float32) + wlo)   # < 0

    rh = r.reshape(b, t, h, hd)
    kh = k.reshape(b, t, h, hd)
    vh = v.reshape(b, t, h, hd)
    lwh = logw.reshape(b, t, h, hd)

    if decode:
        s = state["wkv"].astype(jnp.float32)
        w_t = jnp.exp(lwh[:, 0])                               # (B,H,hd)
        kv = jnp.einsum("bhn,bhm->bhnm", kh[:, 0].astype(jnp.float32),
                        vh[:, 0].astype(jnp.float32))
        y = jnp.einsum("bhn,bhnm->bhm", rh[:, 0].astype(jnp.float32),
                       s + params["u"].astype(jnp.float32)[None, :, :, None]
                       * kv)
        s = s * w_t[..., None] + kv
        y = y[:, None]                                          # (B,1,H,hd)
        new_state = {"wkv": s.astype(state["wkv"].dtype),
                     "shift_t": xin[:, -1, :]}
    else:
        s0 = (state["wkv"] if state is not None else
              jnp.zeros((b, h, hd, hd)))
        y, sf = _wkv_chunked(rh, kh, vh, lwh, params["u"], s0, cfg.chunk)
        new_state = {"wkv": sf.astype(x.dtype), "shift_t": xin[:, -1, :]}

    y = y.reshape(b, t, d).astype(x.dtype)
    y = _group_norm(y, params["ln_x"], h) * g
    out = jnp.einsum("bte,ed->btd", y, params["wo"].astype(x.dtype))
    out = shard(out, ctx, "batch", "act_seq", "act_embed")
    x_mid = x + out

    # ---- channel mixing (on the LN'd stream) ----
    xcin = layer_norm(x_mid, params["ln2"], params["ln2_b"])
    prev_c = state["shift_c"] if decode else None
    xprev = _token_shift(xcin, prev_c)
    cmix = params["cmix"].astype(x.dtype)
    xk2 = xcin + (xprev - xcin) * cmix[0]
    xr2 = xcin + (xprev - xcin) * cmix[1]
    kk = jnp.square(jax.nn.relu(
        jnp.einsum("btd,df->btf", xk2, params["ck"].astype(x.dtype))))
    kk = shard(kk, ctx, "batch", "act_seq", "act_mlp")
    vv = jnp.einsum("btf,fd->btd", kk, params["cv"].astype(x.dtype))
    rr = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr2,
                                   params["cr"].astype(x.dtype)))
    x_out = x_mid + rr * vv

    if state is not None:
        new_state["shift_c"] = xcin[:, -1, :]
        return x_out, new_state
    return x_out, None


def rwkv6_state_shape(cfg: RWKV6Config, batch: int) -> dict:
    h, hd, d = cfg.n_heads, cfg.head_dim, cfg.d_model
    return {"wkv": (batch, h, hd, hd), "shift_t": (batch, d),
            "shift_c": (batch, d)}


def rwkv6_decode_step(params: dict, x_t: jax.Array, state: dict,
                      cfg: RWKV6Config, ctx: ShardingCtx | None
                      ) -> tuple[jax.Array, dict]:
    """x_t: (B,D) -> (y (B,D), new_state). Wraps apply with T=1."""
    y, new_state = rwkv6_apply(params, x_t[:, None, :], cfg, ctx, state)
    return y[:, 0, :], new_state
