"""Mamba2 (SSD) block — chunked state-space duality scan [arXiv:2405.21060].

The block's causal conv1d is the repro.ops ``causal_conv1d`` family — the paper's
C3 window pipeline in one dimension (decode keeps a (K-1)-deep ring state,
literally a WINDOW_BUFFER; DESIGN.md §5, zamba2 row).

SSD semantics (ngroups=1, following the paper's minimal reference):
  h_t = exp(dt_t · A) · h_{t-1} + dt_t · B_t ⊗ x_t        (per head)
  y_t = C_t · h_t + D · x_t
computed chunkwise: intra-chunk via a masked attention-like contraction,
inter-chunk via a scan over per-chunk states — O(T·P·N) not O(T²).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.conv import causal_conv1d_step
from repro.ops import causal_conv1d
from repro.models.common import dense_init, rms_norm
from repro.sharding.logical import A, ShardingCtx, shard

__all__ = ["Mamba2Config", "mamba2_init", "mamba2_axes", "mamba2_apply",
           "mamba2_decode_step", "mamba2_state_shape"]


@dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128
    # contraction dtype for the SSD einsums. Decay accumulation (cumsum,
    # segsum, exp) always runs fp32; bf16 contractions halve the dominant
    # byte traffic of the chunked scan (§Perf zamba2 iteration).
    ssd_bf16: bool = False

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        assert self.d_inner % self.head_dim == 0
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.d_state


def mamba2_init(key: jax.Array, cfg: Mamba2Config) -> dict:
    ks = jax.random.split(key, 4)
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_heads
    # in_proj -> [z (di), x (di), B (n), C (n), dt (h)]
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * n + h), d),
        "conv_w": dense_init(ks[1], (cfg.d_conv, cfg.conv_dim),
                             cfg.d_conv),
        "conv_b": jnp.zeros((cfg.conv_dim,)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)),
        "D": jnp.ones((h,)),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (h,)) * 3.0 - 5.0))),
        "norm": jnp.ones((di,)),
        "out_proj": dense_init(ks[3], (di, d), di),
    }


def mamba2_axes(cfg: Mamba2Config) -> dict:
    return {
        "in_proj": A("embed", "ssm_inner"),
        "conv_w": A(None, "ssm_inner"),
        "conv_b": A("ssm_inner"),
        "A_log": A("ssm_heads"),
        "D": A("ssm_heads"),
        "dt_bias": A("ssm_heads"),
        "norm": A("ssm_inner"),
        "out_proj": A("ssm_inner", "embed"),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """(…, q) -> (…, q, q) lower-triangular segment sums:
    out[..., i, j] = Σ_{k=j+1..i} x[..., k] for i >= j, -inf above diag."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def _ssd_chunked(x, dt, a, b, c, cfg: Mamba2Config):
    """Chunked SSD. x: (B,T,H,P); dt: (B,T,H); a: (H,) (negative);
    b, c: (B,T,N). Returns (y (B,T,H,P), final_state (B,H,P,N))."""
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    q = cfg.chunk
    assert t % q == 0, (t, q)
    nc = t // q

    # discretize: decay log per step = dt * a  (a < 0); input scaled by dt
    da = dt * a[None, None, :]                          # (B,T,H)
    xs = x * dt[..., None]                              # (B,T,H,P)

    r = lambda z, shp: z.reshape(shp)
    da_c = r(da, (bsz, nc, q, h))
    xs_c = r(xs, (bsz, nc, q, h, p))
    b_c = r(b, (bsz, nc, q, n))
    c_c = r(c, (bsz, nc, q, n))

    cdt = jnp.bfloat16 if cfg.ssd_bf16 else jnp.float32

    # 1. intra-chunk (diagonal blocks): attention-like with decay kernel
    l = jnp.exp(_segsum(jnp.moveaxis(da_c, -1, 2)))     # (B,nc,H,q,q)
    y_diag = jnp.einsum("bzin,bzjn,bzhij,bzjhp->bzihp",
                        c_c.astype(cdt), b_c.astype(cdt), l.astype(cdt),
                        xs_c.astype(cdt)).astype(jnp.float32)

    # 2. per-chunk final states
    da_cum = jnp.cumsum(da_c, axis=2)                   # (B,nc,q,H)
    decay_states = jnp.exp(da_cum[:, :, -1:, :] - da_cum)   # (B,nc,q,H)
    states = jnp.einsum("bzjn,bzjh,bzjhp->bzhpn",
                        b_c.astype(cdt), decay_states.astype(cdt),
                        xs_c.astype(cdt)).astype(jnp.float32)

    # 3. inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(da_cum[:, :, -1, :])          # (B,nc,H)

    def scanf(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry                               # emit state BEFORE chunk

    init = jnp.zeros((bsz, h, p, n), x.dtype)
    final, prev_states = jax.lax.scan(
        scanf, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)       # (B,nc,H,P,N)

    # 4. chunk-input contribution
    state_decay = jnp.exp(da_cum)                       # (B,nc,q,H)
    y_off = jnp.einsum("bzin,bzih,bzhpn->bzihp",
                       c_c.astype(cdt), state_decay.astype(cdt),
                       prev_states.astype(cdt)).astype(jnp.float32)

    y = (y_diag + y_off).reshape(bsz, t, h, p)
    return y, final


def mamba2_apply(params: dict, x: jax.Array, cfg: Mamba2Config,
                 ctx: ShardingCtx | None, *, return_state: bool = False):
    """x: (B,T,D) -> (B,T,D) [, final state]. Train/prefill (chunked scan).

    return_state: also return {"ssm","conv"} so serving can continue with
    mamba2_decode_step after a prefill (states start from zero)."""
    bsz, t, d = x.shape
    di, n, h = cfg.d_inner, cfg.d_state, cfg.n_heads

    zxbcdt = jnp.einsum("btd,de->bte", x, params["in_proj"].astype(x.dtype))
    z, xb, b, c, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    xbc_pre = jnp.concatenate([xb, b, c], axis=-1)
    xbc = jax.nn.silu(causal_conv1d(xbc_pre,
                                    params["conv_w"].astype(x.dtype),
                                    params["conv_b"].astype(x.dtype)))
    xb, b, c = jnp.split(xbc, [di, di + n], axis=-1)
    xb = shard(xb, ctx, "batch", "act_seq", "ssm_inner")

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["A_log"].astype(jnp.float32))

    xh = xb.reshape(bsz, t, h, cfg.head_dim)
    y, final = _ssd_chunked(xh.astype(jnp.float32), dt, a,
                            b.astype(jnp.float32), c.astype(jnp.float32), cfg)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] \
        * xh.astype(jnp.float32)
    y = y.reshape(bsz, t, di).astype(x.dtype)

    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    out = jnp.einsum("bte,ed->btd", y, params["out_proj"].astype(x.dtype))
    out = shard(out, ctx, "batch", "act_seq", "act_embed")
    if return_state:
        km1 = cfg.d_conv - 1
        conv_tail = xbc_pre[:, -km1:, :] if t >= km1 else jnp.pad(
            xbc_pre, ((0, 0), (km1 - t, 0), (0, 0)))
        state = {"ssm": final.astype(x.dtype), "conv": conv_tail}
        return out, state
    return out


def mamba2_state_shape(cfg: Mamba2Config, batch: int) -> dict:
    return {
        "ssm": (batch, cfg.n_heads, cfg.head_dim, cfg.d_state),
        "conv": (batch, cfg.d_conv - 1, cfg.conv_dim),
    }


def mamba2_decode_step(params: dict, x_t: jax.Array, state: dict,
                       cfg: Mamba2Config, ctx: ShardingCtx | None
                       ) -> tuple[jax.Array, dict]:
    """Single-token recurrent step. x_t: (B,D); state: {"ssm","conv"}."""
    bsz, d = x_t.shape
    di, n, h = cfg.d_inner, cfg.d_state, cfg.n_heads

    zxbcdt = jnp.einsum("bd,de->be", x_t, params["in_proj"].astype(x_t.dtype))
    z, xb, b, c, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    xbc = jnp.concatenate([xb, b, c], axis=-1)
    xbc, conv_state = causal_conv1d_step(
        xbc, state["conv"], params["conv_w"].astype(x_t.dtype),
        params["conv_b"].astype(x_t.dtype))
    xbc = jax.nn.silu(xbc)
    xb, b, c = jnp.split(xbc, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,H)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))              # (H,)
    decay = jnp.exp(dt * a[None, :])                               # (B,H)

    xh = xb.reshape(bsz, h, cfg.head_dim).astype(jnp.float32)
    ssm = state["ssm"].astype(jnp.float32)
    ssm = ssm * decay[:, :, None, None] \
        + jnp.einsum("bh,bn,bhp->bhpn", dt, b.astype(jnp.float32), xh)
    y = jnp.einsum("bn,bhpn->bhp", c.astype(jnp.float32), ssm)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(bsz, di).astype(x_t.dtype)

    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    out = jnp.einsum("be,ed->bd", y, params["out_proj"].astype(x_t.dtype))
    return out, {"ssm": ssm.astype(state["ssm"].dtype), "conv": conv_state}
