"""Encoder–decoder transformer (seamless-m4t backbone).

The speech/text modality frontends are STUBS per the task spec:
``input_specs`` supplies precomputed frame embeddings (B, T_enc, D) to the
encoder; the real model's conv subsampler (strided 1-D convs — a direct use
of the paper's window pipeline, see DESIGN.md §5) is represented by
core.conv in the smoke test, not in the dry-run graph.

Decoder: causal self-attention + cross-attention to encoder output. Serving
caches both the self KV (rolling) and the cross KV (computed once at
prefill from the encoder output).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import (chunked_cross_entropy, cross_entropy_loss,
                                 decode_q_pos, dense_init, rms_norm,
                                 stacked_init)
from repro.models.layers import (AttnConfig, MLPConfig, attention, attn_axes,
                                 attn_init, mlp_apply, mlp_axes, mlp_init)
from repro.sharding.logical import A, ShardingCtx, shard

__all__ = ["EncDecConfig", "EncDecLM"]


@dataclass(frozen=True)
class EncDecConfig:
    name: str
    n_enc_layers: int
    n_dec_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    act: str = "gelu"
    gated: bool = False
    dtype: Any = jnp.bfloat16
    remat: str = "full"

    @property
    def hd(self) -> int:
        return self.d_model // self.n_heads

    @property
    def attn_cfg(self) -> AttnConfig:
        return AttnConfig(d_model=self.d_model, n_heads=self.n_heads,
                          n_kv_heads=self.n_kv_heads, head_dim=self.hd)

    @property
    def mlp_cfg(self) -> MLPConfig:
        return MLPConfig(d_model=self.d_model, d_ff=self.d_ff, act=self.act,
                         gated=self.gated)

    def param_count(self) -> int:
        d = self.d_model
        attn = 4 * d * d
        mlp = (3 if self.gated else 2) * d * self.d_ff
        enc = self.n_enc_layers * (attn + mlp + 2 * d)
        dec = self.n_dec_layers * (2 * attn + mlp + 3 * d)
        return enc + dec + self.vocab * d + 2 * d

    active_param_count = param_count


class EncDecLM:
    def __init__(self, cfg: EncDecConfig):
        self.cfg = cfg

    # ---------- params ----------
    def _enc_layer_init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {"attn": attn_init(k1, cfg.attn_cfg),
                "mlp": mlp_init(k2, cfg.mlp_cfg),
                "ln1": jnp.ones((cfg.d_model,)),
                "ln2": jnp.ones((cfg.d_model,))}

    def _dec_layer_init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        return {"self_attn": attn_init(k1, cfg.attn_cfg),
                "cross_attn": attn_init(k2, cfg.attn_cfg),
                "mlp": mlp_init(k3, cfg.mlp_cfg),
                "ln1": jnp.ones((cfg.d_model,)),
                "ln2": jnp.ones((cfg.d_model,)),
                "ln3": jnp.ones((cfg.d_model,))}

    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        ke, k1, k2 = jax.random.split(key, 3)
        return {
            "embedding": dense_init(ke, (cfg.vocab, cfg.d_model), cfg.d_model),
            "enc_layers": stacked_init(self._enc_layer_init, k1,
                                       cfg.n_enc_layers),
            "dec_layers": stacked_init(self._dec_layer_init, k2,
                                       cfg.n_dec_layers),
            "enc_norm": jnp.ones((cfg.d_model,)),
            "final_norm": jnp.ones((cfg.d_model,)),
        }

    def axes(self) -> dict:
        cfg = self.cfg
        enc_ax = {"attn": attn_axes(cfg.attn_cfg),
                  "mlp": mlp_axes(cfg.mlp_cfg),
                  "ln1": A(None), "ln2": A(None)}
        dec_ax = {"self_attn": attn_axes(cfg.attn_cfg),
                  "cross_attn": attn_axes(cfg.attn_cfg),
                  "mlp": mlp_axes(cfg.mlp_cfg),
                  "ln1": A(None), "ln2": A(None), "ln3": A(None)}
        stack = lambda ax: jax.tree_util.tree_map(
            lambda a: A("layers", *a.names), ax,
            is_leaf=lambda v: isinstance(v, A))
        return {"embedding": A("vocab", "embed"),
                "enc_layers": stack(enc_ax), "dec_layers": stack(dec_ax),
                "enc_norm": A(None), "final_norm": A(None)}

    # ---------- encoder ----------
    def encode(self, params: dict, frames: jax.Array,
               ctx: ShardingCtx | None) -> jax.Array:
        """frames: (B, T_enc, D) stub embeddings -> encoder output."""
        cfg = self.cfg
        x = shard(frames.astype(cfg.dtype), ctx, "batch", "act_seq",
                  "act_embed")
        t = x.shape[1]
        pos = jnp.broadcast_to(jnp.arange(t), x.shape[:2])

        def body(xcur, p):
            h = rms_norm(xcur, p["ln1"])
            a, _ = attention(p["attn"], h, cfg.attn_cfg, ctx, q_pos=pos,
                             causal=False)
            xcur = xcur + a
            h = rms_norm(xcur, p["ln2"])
            return xcur + mlp_apply(p["mlp"], h, cfg.mlp_cfg, ctx), None

        if cfg.remat != "none":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable,
                prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return rms_norm(x, params["enc_norm"])

    # ---------- decoder ----------
    def _decode_layers(self, params: dict, x: jax.Array, enc_out: jax.Array,
                       ctx: ShardingCtx | None, *, q_pos,
                       self_cache: dict | None, cross_kv: dict | None,
                       cache_index):
        cfg = self.cfg

        def body(xcur, xs):
            p, sc, ckv = xs
            h = rms_norm(xcur, p["ln1"])
            cache_kv = None if sc is None else (sc["k"], sc["v"])
            a, new_kv = attention(p["self_attn"], h, cfg.attn_cfg, ctx,
                                  q_pos=q_pos, causal=True,
                                  cache_kv=cache_kv, cache_index=cache_index)
            xcur = xcur + a
            h = rms_norm(xcur, p["ln2"])
            if ckv is not None:
                c, _ = attention(p["cross_attn"], h, cfg.attn_cfg, ctx,
                                 q_pos=q_pos, causal=False,
                                 precomputed_kv=(ckv["k"], ckv["v"]))
            else:
                c, _ = attention(p["cross_attn"], h, cfg.attn_cfg, ctx,
                                 q_pos=q_pos, causal=False, kv_x=enc_out)
            xcur = xcur + c
            h = rms_norm(xcur, p["ln3"])
            xcur = xcur + mlp_apply(p["mlp"], h, cfg.mlp_cfg, ctx)
            ys = None if new_kv is None else {"k": new_kv[0], "v": new_kv[1]}
            return xcur, ys

        if cfg.remat != "none":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable,
                prevent_cse=False)
        return jax.lax.scan(body, x, (params["dec_layers"], self_cache,
                                      cross_kv))

    def _cross_kv(self, params: dict, enc_out: jax.Array) -> dict:
        """Per-layer cross K/V from the encoder output (prefill-time)."""
        def one(p):
            k = jnp.einsum("btd,dhk->bthk", enc_out,
                           p["cross_attn"]["wk"].astype(enc_out.dtype))
            v = jnp.einsum("btd,dhk->bthk", enc_out,
                           p["cross_attn"]["wv"].astype(enc_out.dtype))
            return {"k": k, "v": v}

        return jax.vmap(one)(params["dec_layers"])

    def _logits(self, params: dict, x: jax.Array,
                ctx: ShardingCtx | None) -> jax.Array:
        x = rms_norm(x, params["final_norm"])
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["embedding"].astype(x.dtype))
        return shard(logits.astype(jnp.float32), ctx,
                     "batch", "act_seq", "act_vocab")

    # ---------- public ----------
    def loss(self, params: dict, batch: dict,
             ctx: ShardingCtx | None = None) -> tuple[jax.Array, dict]:
        """batch: frames (B,T_enc,D), tokens (B,T_dec), labels (B,T_dec)."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"], ctx)
        x = params["embedding"][batch["tokens"]].astype(cfg.dtype)
        x = shard(x, ctx, "batch", "act_seq", "act_embed")
        pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        x, _ = self._decode_layers(params, x, enc_out, ctx, q_pos=pos,
                                   self_cache=None, cross_kv=None,
                                   cache_index=None)
        x = rms_norm(x, params["final_norm"])
        ce = chunked_cross_entropy(x, params["embedding"], batch["labels"],
                                   mask=batch.get("loss_mask"))
        return ce, {"ce": ce}

    def init_cache(self, batch: int, max_seq: int,
                   enc_seq: int | None = None) -> dict:
        """max_seq: decoder self-cache length; enc_seq: cross KV length."""
        cfg = self.cfg
        enc_seq = enc_seq or max_seq
        l, kv, hd = cfg.n_dec_layers, cfg.n_kv_heads, cfg.hd
        return {
            "self": {"k": jnp.zeros((l, batch, max_seq, kv, hd), cfg.dtype),
                     "v": jnp.zeros((l, batch, max_seq, kv, hd), cfg.dtype)},
            "cross": {"k": jnp.zeros((l, batch, enc_seq, kv, hd), cfg.dtype),
                      "v": jnp.zeros((l, batch, enc_seq, kv, hd), cfg.dtype)},
        }

    def cache_axes(self) -> dict:
        kvax = {"k": A("layers", "batch", "kv_seq", "kv_heads", None),
                "v": A("layers", "batch", "kv_seq", "kv_heads", None)}
        return {"self": dict(kvax), "cross": dict(kvax)}

    def prefill(self, params: dict, batch: dict, cache: dict,
                ctx: ShardingCtx | None = None) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"], ctx)
        cross = self._cross_kv(params, enc_out)
        cross = jax.tree_util.tree_map(
            lambda a, ref: a.astype(ref.dtype), cross, cache["cross"])
        x = params["embedding"][batch["tokens"]].astype(cfg.dtype)
        pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        x, new_self = self._decode_layers(
            params, x, enc_out, ctx, q_pos=pos, self_cache=cache["self"],
            cross_kv=cross, cache_index=jnp.zeros((), jnp.int32))
        logits = self._logits(params, x[:, -1:, :], ctx)
        return logits[:, 0, :], {"self": new_self, "cross": cross}

    def decode_step(self, params: dict, tokens: jax.Array, pos: jax.Array,
                    cache: dict, ctx: ShardingCtx | None = None
                    ) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        x = params["embedding"][tokens[:, None]].astype(cfg.dtype)
        q_pos = decode_q_pos(pos, x.shape[0])
        x, new_self = self._decode_layers(
            params, x, None, ctx, q_pos=q_pos, self_cache=cache["self"],
            cross_kv=cache["cross"], cache_index=pos)
        logits = self._logits(params, x, ctx)
        return logits[:, 0, :], {"self": new_self, "cross": cache["cross"]}
