"""Shared model components: norms, RoPE, activations, init helpers."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.sharding.logical import A

__all__ = ["dense_init", "stacked_init", "rms_norm", "layer_norm",
           "rope_freqs", "apply_rope", "softcap", "ACTIVATIONS",
           "cross_entropy_loss", "chunked_cross_entropy",
           "take_last_logits", "decode_q_pos"]


def decode_q_pos(pos: jax.Array, batch: int) -> jax.Array:
    """Query positions (B, 1) for a single-token decode step.

    ``pos`` is either a scalar (whole batch at one position — the legacy
    lock-step decode) or a (B,) vector of per-sequence positions (slot-based
    continuous batching, DESIGN.md §6: every slot advances independently).
    """
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        return jnp.broadcast_to(pos[None, None], (batch, 1))
    return pos[:, None]


def dense_init(key: jax.Array, shape: tuple[int, ...], fan_in: int,
               dtype=jnp.float32) -> jax.Array:
    """Truncated-normal fan-in init (std = 1/sqrt(fan_in))."""
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)
            * fan_in ** -0.5)


def stacked_init(init_fn: Callable[[jax.Array], dict], key: jax.Array,
                 n: int) -> dict:
    """vmap an init over ``n`` layer keys -> params stacked on a leading dim."""
    return jax.vmap(init_fn)(jax.random.split(key, n))


def rms_norm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
             plus_one: bool = False) -> jax.Array:
    """RMSNorm in fp32 (mixed-precision safe). ``plus_one``: gemma-style
    (1 + w) scaling so zero-init means identity."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    w = scale.astype(jnp.float32)
    return (x * ((1.0 + w) if plus_one else w)).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array | None = None,
               *, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    x = x * scale.astype(jnp.float32)
    if bias is not None:
        x = x + bias.astype(jnp.float32)
    return x.astype(dt)


def rope_freqs(positions: jax.Array, head_dim: int,
               theta: float = 10000.0) -> tuple[jax.Array, jax.Array]:
    """positions (…,) -> (cos, sin) each (…, head_dim/2), fp32."""
    half = head_dim // 2
    inv = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (B?, S, D/2) broadcastable. Split-half RoPE."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :] if cos.ndim == x.ndim - 1 else cos
    s = sin[..., None, :] if sin.ndim == x.ndim - 1 else sin
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(dt)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


ACTIVATIONS: dict[str, Callable[[jax.Array], jax.Array]] = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
    "relu_sq": lambda x: jnp.square(jax.nn.relu(x)),
}


def chunked_cross_entropy(x: jax.Array, weight: jax.Array,
                          labels: jax.Array, *,
                          transpose_weight: bool = False,
                          final_softcap: float | None = None,
                          mask: jax.Array | None = None,
                          chunk: int = 8_192) -> jax.Array:
    """Cross entropy without materializing the (B,S,V) logits.

    The (tokens × vocab) logits tensor at 256k-vocab training shapes is tens
    of GB per device in fp32; this computes an online logsumexp over vocab
    chunks (one scan step per chunk, remat'd so only the running reductions
    are saved). Functionally identical to softmax CE on full logits.

    x: (B,S,D) final hidden; weight: (V,D) tied embedding or (D,V) lm_head
    (transpose_weight=True). labels: (B,S) int.
    """
    b, s, d = x.shape
    if transpose_weight:
        weight = weight.T                      # -> (V, D)
    v = weight.shape[0]
    n_chunks = -(-v // chunk)
    pad_v = n_chunks * chunk - v
    if pad_v:
        weight = jnp.pad(weight, ((0, pad_v), (0, 0)))
    w_c = weight.reshape(n_chunks, chunk, d)

    xt = x.reshape(b * s, d)
    lab = labels.reshape(b * s)

    def body(carry, inp):
        run_max, run_sum, lab_logit = carry
        wc, ci = inp
        logits = jnp.einsum("td,cd->tc", xt.astype(jnp.float32),
                            wc.astype(jnp.float32))
        if final_softcap is not None:
            logits = final_softcap * jnp.tanh(logits / final_softcap)
        if pad_v:
            col = jnp.arange(chunk) + ci * chunk
            logits = jnp.where(col[None, :] < v, logits, -jnp.inf)
        cmax = logits.max(-1)
        new_max = jnp.maximum(run_max, cmax)
        run_sum = run_sum * jnp.exp(run_max - new_max) + \
            jnp.exp(logits - new_max[:, None]).sum(-1)
        local = lab - ci * chunk
        in_chunk = (local >= 0) & (local < chunk)
        picked = jnp.take_along_axis(
            logits, jnp.clip(local, 0, chunk - 1)[:, None], axis=-1)[:, 0]
        lab_logit = lab_logit + jnp.where(in_chunk, picked, 0.0)
        return (new_max, run_sum, lab_logit), None

    init = (jnp.full((b * s,), -jnp.inf, jnp.float32),
            jnp.zeros((b * s,), jnp.float32),
            jnp.zeros((b * s,), jnp.float32))
    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable,
                          prevent_cse=False)
    (fmax, fsum, flab), _ = jax.lax.scan(
        body, init, (w_c, jnp.arange(n_chunks)))
    nll = (fmax + jnp.log(fsum)) - flab
    if mask is not None:
        m = mask.reshape(b * s).astype(jnp.float32)
        return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)
    return nll.mean()


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: jax.Array | None = None) -> jax.Array:
    """Token-mean cross entropy in fp32. logits (B,S,V), labels (B,S) int."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        m = mask.astype(jnp.float32)
        return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)
    return nll.mean()


def take_last_logits(logits: jax.Array) -> jax.Array:
    return logits[:, -1, :]
