"""Mixture-of-Experts with capacity-bounded top-k routing (GShard-style).

Dispatch is scatter-based (no (T, E, C) one-hot tensor): each (token, k)
assignment computes its position-within-expert by a cumulative count, drops
past capacity, and scatters features into an (E·C, D) buffer. Compiled
FLOPs are therefore ∝ E·C·D·F = active-expert compute (what the roofline's
MODEL_FLOPS/HLO_FLOPs ratio expects), not all-expert compute.

Experts are sharded over the ``model`` mesh axis (EP). Under pjit, the
scatter/gather across the token and expert shardings lowers to the dispatch
collectives; the shard_map all-to-all variant is a §Perf iteration.

Supports shared (always-on) experts (llama4-scout) and top-k renorm (dbrx).
An auxiliary load-balance loss (Switch-style) is returned for training.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.common import ACTIVATIONS, dense_init
from repro.sharding.compat import shard_map
from repro.sharding.logical import A, ShardingCtx, shard

__all__ = ["MoEConfig", "moe_init", "moe_axes", "moe_apply"]


@dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                 # per-expert hidden size
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    n_shared: int = 0         # always-on shared experts (llama4: 1)
    act: str = "silu"
    gated: bool = True
    router_aux_weight: float = 0.01


def moe_init(key: jax.Array, cfg: MoEConfig) -> dict:
    ks = jax.random.split(key, 6)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    p = {
        "router": dense_init(ks[0], (d, e), d),
        "wi": dense_init(ks[1], (e, d, f), d),
        "wo": dense_init(ks[2], (e, f, d), f),
    }
    if cfg.gated:
        p["wg"] = dense_init(ks[3], (e, d, f), d)
    if cfg.n_shared:
        p["shared_wi"] = dense_init(ks[4], (d, cfg.n_shared * f), d)
        p["shared_wo"] = dense_init(ks[5], (cfg.n_shared * f, d),
                                    cfg.n_shared * f)
        if cfg.gated:
            p["shared_wg"] = dense_init(ks[4], (d, cfg.n_shared * f), d)
    return p


def moe_axes(cfg: MoEConfig) -> dict:
    ax = {
        "router": A("embed", None),
        "wi": A("expert", "embed", "mlp"),
        "wo": A("expert", "mlp", "embed"),
    }
    if cfg.gated:
        ax["wg"] = A("expert", "embed", "mlp")
    if cfg.n_shared:
        ax["shared_wi"] = A("embed", "mlp")
        ax["shared_wo"] = A("mlp", "embed")
        if cfg.gated:
            ax["shared_wg"] = A("embed", "mlp")
    return ax


def _capacity(tokens: int, cfg: MoEConfig) -> int:
    c = math.ceil(tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # multiple of 8 (sublane), never pow2-padded


def moe_apply(params: dict, x: jax.Array, cfg: MoEConfig,
              ctx: ShardingCtx | None) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar fp32).

    Dispatches to the shard_map expert-parallel path when a mesh with a
    'model' axis that divides n_experts is available (the production path),
    else runs the local reference implementation below.
    """
    if (ctx is not None and ctx.mesh is not None
            and "model" in ctx.mesh.axis_names):
        n_model = dict(zip(ctx.mesh.axis_names,
                           ctx.mesh.devices.shape))["model"]
        if cfg.n_experts % n_model == 0:
            return _moe_apply_ep(params, x, cfg, ctx, n_model)
    return _moe_apply_local(params, x, cfg, ctx)


def _moe_apply_ep(params: dict, x: jax.Array, cfg: MoEConfig,
                  ctx: ShardingCtx, n_model: int
                  ) -> tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE via shard_map — zero all-to-all by construction.

    Activations between layers are replicated over the 'model' axis (the
    standard TP layout), so every model rank already holds every local
    token: rank j selects the tokens routed to ITS E/n experts, runs them
    (capacity per (expert, data-shard) group — GShard group semantics),
    and a single psum over 'model' combines — the same collective cost as
    one row-parallel TP matmul. The shared expert's F dim is sharded over
    'model' and its partial output rides the same psum for free.

    This exists because the pjit scatter/gather formulation of EP dispatch
    makes the SPMD partitioner materialize replicated (T·k, D) token
    buffers — ~50 GB/device at dbrx train shapes (measured in the dry-run;
    see EXPERIMENTS.md §Perf).
    """
    mesh = ctx.mesh
    e, k = cfg.n_experts, cfg.top_k
    e_l = e // n_model
    act = ACTIVATIONS[cfg.act]
    sizes = dict(mesh.shape)
    dp_axes: tuple = ()
    for cand in (("pod", "data"), ("data",), ("pod",)):
        if all(a in mesh.axis_names for a in cand):
            prod = 1
            for a in cand:
                prod *= sizes[a]
            if prod > 1 and x.shape[0] % prod == 0:
                dp_axes = cand
                break
    bspec = dp_axes if dp_axes else None

    def local(xl, router, wi, wg, wo, sh_wi, sh_wg, sh_wo):
        bl, s, d = xl.shape
        t = bl * s
        cap = _capacity(s * bl, cfg)
        j = jax.lax.axis_index("model")

        logits = jnp.einsum("bsd,de->bse", xl.astype(jnp.float32),
                            router.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = jax.lax.top_k(probs, k)            # (B,S,k)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
        assign = jax.nn.one_hot(top_e[..., 0], e, dtype=jnp.float32)
        aux = e * jnp.mean(assign.mean((0, 1)) * probs.mean((0, 1))) \
            * cfg.router_aux_weight
        if dp_axes:
            # per-data-shard estimator averaged across shards (mean of
            # per-shard products — GShard computes aux per group likewise;
            # differs from the exact global statistic at O(1/shards) level)
            aux = jax.lax.pmean(aux, dp_axes)

        flat_e = top_e.reshape(t * k)
        local_e = flat_e - j * e_l
        owned = (local_e >= 0) & (local_e < e_l)
        le = jnp.where(owned, local_e, e_l)               # drop row e_l
        onehot = jax.nn.one_hot(le, e_l + 1, dtype=jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1
        keep = owned & (pos < cap)
        pos_c = jnp.where(keep, pos, cap)
        le_c = jnp.where(keep, le, e_l)

        xt = xl.reshape(t, d)
        src = jnp.repeat(jnp.arange(t), k)
        buf = jnp.zeros((e_l + 1, cap + 1, d), xl.dtype)
        buf = buf.at[le_c, pos_c].set(xt[src])
        buf = buf[:e_l, :cap, :]

        hid = jnp.einsum("ecd,edf->ecf", buf, wi.astype(xl.dtype))
        if cfg.gated:
            hid = act(jnp.einsum("ecd,edf->ecf", buf,
                                 wg.astype(xl.dtype))) * hid
        else:
            hid = act(hid)
        y = jnp.einsum("ecf,efd->ecd", hid, wo.astype(xl.dtype))
        y = jnp.pad(y, ((0, 1), (0, 1), (0, 0)))
        gathered = y[le_c, pos_c]                         # (t·k, D)
        w = (top_w.reshape(t * k) * keep).astype(xl.dtype)
        out = (gathered * w[:, None]).reshape(t, k, d).sum(1)

        if cfg.n_shared:                                  # F sharded: partial
            sh = jnp.einsum("td,df->tf", xt, sh_wi.astype(xl.dtype))
            if cfg.gated:
                sh = act(jnp.einsum("td,df->tf", xt,
                                    sh_wg.astype(xl.dtype))) * sh
            else:
                sh = act(sh)
            out = out + jnp.einsum("tf,fd->td", sh, sh_wo.astype(xl.dtype))

        out = jax.lax.psum(out, "model")
        return out.reshape(bl, s, d), aux

    zero = jnp.zeros((), x.dtype)
    # cast to the compute dtype BEFORE the shard_map boundary so the FSDP
    # all-gather of expert weights moves bf16, not fp32 — halves both the
    # gather buffers (the dbrx train cell over-budget) and the traffic.
    cast = lambda t: t.astype(x.dtype)
    args = (x, params["router"], cast(params["wi"]),
            cast(params["wg"]) if cfg.gated else zero, cast(params["wo"]),
            cast(params["shared_wi"]) if cfg.n_shared else zero,
            cast(params["shared_wg"]) if (cfg.n_shared and cfg.gated)
            else zero,
            cast(params["shared_wo"]) if cfg.n_shared else zero)
    in_specs = (P(bspec, None, None), P(None, None),
                P("model", None, None), P("model", None, None) if cfg.gated
                else P(), P("model", None, None),
                P(None, "model") if cfg.n_shared else P(),
                P(None, "model") if (cfg.n_shared and cfg.gated) else P(),
                P("model", None) if cfg.n_shared else P())
    out, aux = shard_map(local, mesh=mesh, in_specs=in_specs,
                         out_specs=(P(bspec, None, None), P()),
                         check_vma=False)(*args)
    return shard(out, ctx, "batch", "act_seq", "act_embed"), aux


def _moe_apply_local(params: dict, x: jax.Array, cfg: MoEConfig,
                     ctx: ShardingCtx | None) -> tuple[jax.Array, jax.Array]:
    """Reference (single-host) path.

    GShard-style GROUP-WISE dispatch: each batch row is a dispatch group
    with its own capacity C = ceil(S·k·cf/E). All cumulative counts,
    scatters and gathers act within a row.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(s, cfg)
    act = ACTIVATIONS[cfg.act]

    # --- routing (fp32) ---
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                # (B, S, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: E * Σ_e (token fraction_e × mean prob_e)
    assign = jax.nn.one_hot(top_e[..., 0], e, dtype=jnp.float32)
    aux = e * jnp.mean(assign.mean((0, 1)) * probs.mean((0, 1))) \
        * cfg.router_aux_weight

    # --- group-local dispatch: position-within-(row, expert) ---
    flat_e = top_e.reshape(b, s * k)                      # (B, S·k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)   # (B, S·k, E)
    pos = (jnp.cumsum(onehot, axis=1) * onehot).sum(-1) - 1
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap)                     # drop slot: col C
    src = jnp.repeat(jnp.arange(s), k)                    # within-row token

    buf = jnp.zeros((b, e, cap + 1, d), x.dtype)
    buf = shard(buf, ctx, "batch", "act_expert", None, None)
    brow = jnp.broadcast_to(jnp.arange(b)[:, None], (b, s * k))
    buf = buf.at[brow, flat_e, pos_c].set(x[:, src, :].reshape(b, s * k, d))
    buf = buf[:, :, :cap, :]
    buf = shard(buf, ctx, "batch", "act_expert", None, None)

    # --- expert FFN (B, E, C, D), experts sharded on 'model' (EP) ---
    hid = jnp.einsum("becd,edf->becf", buf, params["wi"].astype(x.dtype))
    if cfg.gated:
        gate = jnp.einsum("becd,edf->becf", buf,
                          params["wg"].astype(x.dtype))
        hid = act(gate) * hid
    else:
        hid = act(hid)
    hid = shard(hid, ctx, "batch", "act_expert", None, None)
    y = jnp.einsum("becf,efd->becd", hid, params["wo"].astype(x.dtype))

    # --- combine: row-local gather + routing weights ---
    y = jnp.pad(y, ((0, 0), (0, 0), (0, 1), (0, 0)))      # drop slot row
    gathered = y[brow, flat_e, pos_c]                     # (B, S·k, D)
    w = (top_w.reshape(b, s * k) * keep).astype(x.dtype)
    out = (gathered * w[..., None]).reshape(b, s, k, d).sum(axis=2)

    # --- shared experts (always-on) ---
    if cfg.n_shared:
        sh = jnp.einsum("bsd,df->bsf", x, params["shared_wi"].astype(x.dtype))
        if cfg.gated:
            sg = jnp.einsum("bsd,df->bsf", x,
                            params["shared_wg"].astype(x.dtype))
            sh = act(sg) * sh
        else:
            sh = act(sh)
        sh = shard(sh, ctx, "batch", "act_seq", "act_mlp")
        out = out + jnp.einsum("bsf,fd->bsd", sh,
                               params["shared_wo"].astype(x.dtype))

    return shard(out, ctx, "batch", "act_seq", "act_embed"), aux
