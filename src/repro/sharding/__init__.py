"""Logical-axis sharding system (MaxText-style rules -> PartitionSpec)."""
from repro.sharding.compat import shard_map
from repro.sharding.logical import (A, ShardingCtx, ShardingRules,
                                    DEFAULT_RULES, SP_DECODE_RULES,
                                    INPUT_PARALLEL_RULES, spec_for, shard,
                                    param_specs, param_shardings)
