"""Logical axis names -> mesh PartitionSpecs, with divisibility guards.

Every tensor in the framework is annotated with *logical* axis names
("batch", "heads", "mlp", …); rules map each name to an ordered list of
candidate mesh axes. ``spec_for`` resolves a concrete PartitionSpec for a
given shape on a given mesh, taking the first candidate whose size divides
the dimension (and which is not already consumed by an earlier dim) — so
every (arch × shape × mesh) combination lowers even when e.g. kv_heads=8
cannot split over model=16.

Parallelism taxonomy realized through the rules (DESIGN.md §4):
  DP    batch          -> ('pod', 'data')
  FSDP  embed (params) -> 'data'    (ZeRO-3: stacked-layer params split)
  TP    heads/mlp/vocab/conv_out -> 'model'   (paper C1 output-channel)
  TP-in conv_in/mlp_in -> 'model'   (paper C1 input-channel, psum variant)
  EP    expert         -> 'model'
  SP    kv_seq         -> 'data' in SP_DECODE_RULES (long-context decode)
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["A", "ShardingRules", "ShardingCtx", "DEFAULT_RULES",
           "SP_DECODE_RULES", "spec_for", "shard", "param_specs",
           "param_shardings"]


class A:
    """Logical-axes annotation for one param — deliberately NOT a pytree
    container (plain tuples would be flattened by tree_map), so an axes
    pytree mirrors the param pytree with ``A`` leaves."""

    __slots__ = ("names",)

    def __init__(self, *names: str | None):
        self.names = names

    def __repr__(self) -> str:
        return f"A{self.names!r}"

    def __eq__(self, other) -> bool:
        return isinstance(other, A) and self.names == other.names

    def __hash__(self) -> int:
        return hash(self.names)

# logical axis -> ordered candidates; each candidate is a mesh-axis name or a
# tuple of mesh-axis names (used together, sizes multiply).
Rules = Mapping[str, Sequence[Any]]

_BASE: dict[str, Sequence[Any]] = {
    # activations
    "batch":      [("pod", "data"), "data"],
    # attention-internal batch dim: defaults to the DP axes; archs whose
    # head count does not divide the TP degree override this to
    # [("data","model"), …] so attention distributes over ALL chips as
    # extra DP instead of replicating per model rank (DESIGN.md §4).
    "attn_batch": [("pod", "data"), "data"],
    "act_seq":    [],                 # unsharded by default
    "act_embed":  [],
    "act_heads":  ["model"],
    "act_kv":     ["model"],
    "act_mlp":    ["model"],
    "act_vocab":  ["model"],
    "act_expert": ["model"],
    # KV-cache sequence dim: sharded over 'model' by default — with GQA
    # (kv_heads < model size) the head dim cannot absorb the model axis, and
    # an unsharded 32k cache is tens of GB/device. XLA turns the softmax over
    # the sharded T dim into small psums (distributed flash-decode).
    "kv_seq":     ["model"],
    # params — weight matrices: TP axis first, then FSDP over 'data'
    "embed":      ["data"],           # FSDP/ZeRO-3 on the d_model dim
    "vocab":      ["model"],
    "heads":      ["model"],
    "kv_heads":   ["model"],
    "head":       [],
    "mlp":        ["model"],
    "expert":     ["model"],
    "conv_out":   ["model"],          # paper C1 output-channel parallel
    "conv_in":    [],                 # becomes 'model' in input-parallel mode
    "conv_spatial": [],
    "layers":     [],                 # stacked scan dim: never sharded
    "ssm_state":  [],
    "ssm_heads":  ["model"],
    "ssm_inner":  ["model"],
}


@dataclass(frozen=True)
class ShardingRules:
    table: Rules = field(default_factory=lambda: dict(_BASE))

    def with_overrides(self, **kw: Sequence[Any]) -> "ShardingRules":
        t = dict(self.table)
        t.update(kw)
        return ShardingRules(t)


DEFAULT_RULES = ShardingRules()
# long-context decode: shard the KV-cache sequence dim over BOTH axes
# (context/sequence parallelism); batch=1 cells don't use 'data' for batch.
SP_DECODE_RULES = DEFAULT_RULES.with_overrides(
    kv_seq=[("data", "model"), "data"], batch=[("pod",)])
# paper Eq. (7) input-channel-parallel mode for conv / row-parallel matmul
INPUT_PARALLEL_RULES = DEFAULT_RULES.with_overrides(
    conv_in=["model"], conv_out=[])


def _axis_size(mesh: Mesh, cand: Any) -> int:
    shape = dict(mesh.shape)  # works for Mesh and AbstractMesh
    if isinstance(cand, tuple):
        size = 1
        for a in cand:
            size *= shape[a]
        return size
    return shape[cand]


def _cand_axes(cand: Any) -> tuple[str, ...]:
    return cand if isinstance(cand, tuple) else (cand,)


def spec_for(mesh: Mesh, shape: Sequence[int], names: Sequence[str | None],
             rules: ShardingRules = DEFAULT_RULES) -> P:
    """Resolve a PartitionSpec for ``shape`` with logical ``names``.

    Guards: a mesh axis is used at most once; a candidate is taken only if
    its total size divides the dim. None / unknown names -> replicated dim.
    """
    assert len(shape) == len(names), (shape, names)
    used: set[str] = set()
    out: list[Any] = []
    for dim, name in zip(shape, names):
        entry = None
        if name is not None:
            for cand in rules.table.get(name, []):
                axes = _cand_axes(cand)
                if any(a not in mesh.axis_names for a in axes):
                    continue
                if any(a in used for a in axes):
                    continue
                size = _axis_size(mesh, cand)
                if size == 1:       # trivial axis: keep the spec clean
                    continue
                if dim % size != 0 or dim == 0:
                    continue
                entry = cand
                used.update(axes)
                break
        out.append(entry)
    # trim trailing Nones for tidier specs
    while out and out[-1] is None:
        out.pop()
    return P(*out)


@dataclass(frozen=True)
class ShardingCtx:
    """Threaded through model code; ``shard`` is a no-op when mesh is None
    (single-device tests) so models run unmodified on CPU."""

    mesh: Mesh | None = None
    rules: ShardingRules = DEFAULT_RULES

    def with_rules(self, rules: ShardingRules) -> "ShardingCtx":
        return replace(self, rules=rules)


def shard(x: jax.Array, ctx: ShardingCtx | None, *names: str | None
          ) -> jax.Array:
    """Annotate ``x`` with a sharding constraint resolved from logical names."""
    if ctx is None or ctx.mesh is None:
        return x
    spec = spec_for(ctx.mesh, x.shape, names, ctx.rules)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def param_specs(shapes: Any, axes: Any, mesh: Mesh,
                rules: ShardingRules = DEFAULT_RULES) -> Any:
    """Map a pytree of ShapeDtypeStructs + a matching pytree of ``A``
    annotations to a pytree of PartitionSpecs."""
    return jax.tree_util.tree_map(
        lambda s, a: spec_for(mesh, s.shape, a.names, rules), shapes, axes)


def param_shardings(shapes: Any, axes: Any, mesh: Mesh,
                    rules: ShardingRules = DEFAULT_RULES) -> Any:
    specs = param_specs(shapes, axes, mesh, rules)
    return jax.tree_util.tree_map(
        lambda sp: NamedSharding(mesh, sp), specs,
        is_leaf=lambda v: isinstance(v, P))
