"""Version-compatible ``shard_map`` import (DESIGN.md §4).

``shard_map`` graduated from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace around jax 0.5; the installed toolchain may be on either
side of that move. Every module that builds explicit-collective code
(``core.parallelism``, ``models.moe``, ``train.compression``) imports the
symbol from here so the repo runs on both.
"""
from __future__ import annotations

import functools
import inspect

try:                                    # jax >= 0.5
    from jax import shard_map as _shard_map
except ImportError:                     # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

if "check_vma" in inspect.signature(_shard_map).parameters:
    shard_map = _shard_map
else:
    @functools.wraps(_shard_map)
    def shard_map(*args, **kwargs):
        # the replication-check kwarg was renamed check_rep -> check_vma;
        # translate so call sites can use the modern spelling everywhere.
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _shard_map(*args, **kwargs)

__all__ = ["shard_map"]
